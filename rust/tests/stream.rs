//! Streaming-ingestion subsystem invariants (docs/STREAMING.md):
//!
//! 1. **`stream=off` is bit-identical**: configuring the param off (or
//!    omitting it) leaves every training metric of all four methods
//!    exactly as before — the same anchor pattern as `shards=1` and
//!    `prefetch=0` (artifact-gated, skips when `make artifacts` has not
//!    run);
//! 2. a streamed run (`stream=RATE`) trains to completion through
//!    epoch-boundary merges and exposes its churn config + invalidation
//!    counters on the session (artifact-gated);
//! 3. **byte-accounting ledger under churn** (artifact-free): cumulative
//!    `h2d == (input − saved_by_cache) + (uploads − saved_by_delta) +
//!    invalidation` — tier invalidation is charged as its own PCIe
//!    traffic and never launders the cache/delta savings;
//! 4. every sampler stays valid across `set_graph` onto a merged CSR;
//! 5. the `stream=` param is plumbed through every method spec, with bad
//!    specs rejected at factory build time and good ones round-tripping
//!    through Display/JSON.

use gns::features::build_dataset;
use gns::graph::{DeltaOverlay, EdgeStream, GraphView, StreamSpec};
use gns::sampling::spec::{BuildContext, MethodRegistry};
use gns::sampling::BlockShapes;
use gns::session::{Session, SessionBuilder};
use gns::tiering::{SamplerPolicy, TieringEngine};
use gns::topology::{LinkClock, TransferStats};
use std::sync::Arc;

const METHODS: [&str; 4] = ["ns", "ladies:s-layer=128", "lazygcn", "gns:cache-fraction=0.02"];

fn with_param(method: &str, param: &str) -> String {
    let sep = if method.contains(':') { "," } else { ":" };
    format!("{method}{sep}{param}")
}

/// The tiny-artifact session the e2e suites share.
fn tiny_session(method: &str) -> SessionBuilder {
    Session::builder("yelp-s", method)
        .scale(0.03)
        .seed(1)
        .epochs(3)
        .workers(1)
        .eval_batches(2)
        .artifact("tiny")
        .refit_features(true)
        .max_train_nodes(512)
        .max_val_nodes(128)
        .paranoid_validate(true)
}

/// Every deterministic per-epoch + run-total metric a config produces.
#[derive(Debug, PartialEq)]
struct Metrics {
    per_epoch: Vec<(u64, u64, u64, usize, u64, u64)>, // (loss, acc, val, batches, h2d, d2d)
    cache_hits: u64,
    cache_misses: u64,
    test_f1: u64,
}

fn run_metrics(builder: SessionBuilder) -> Option<Metrics> {
    let mut session = builder.build_or_skip()?;
    let r = session.run().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    Some(Metrics {
        per_epoch: r
            .reports
            .iter()
            .map(|rep| {
                (
                    rep.mean_loss.to_bits(),
                    rep.train_acc.to_bits(),
                    rep.val_f1.to_bits(),
                    rep.batches,
                    rep.transfer.h2d_bytes,
                    rep.transfer.d2d_bytes,
                )
            })
            .collect(),
        cache_hits: r.cache_hits,
        cache_misses: r.cache_misses,
        test_f1: r.test_f1.to_bits(),
    })
}

// ---------------------------------------------------------------------------
// 1. stream=off ≡ omitted, bit-identical

#[test]
fn stream_off_is_metric_identical_for_all_methods() {
    for method in METHODS {
        let Some(base) = run_metrics(tiny_session(method)) else { return };
        let got = run_metrics(tiny_session(&with_param(method, "stream=off"))).unwrap();
        assert_eq!(got, base, "{method}: stream=off diverged from omitted");
    }
}

// ---------------------------------------------------------------------------
// 2. a streamed run trains through merges

#[test]
fn streamed_run_trains_through_epoch_boundary_merges() {
    let method = with_param(METHODS[3], "stream=16");
    let Some(mut session) = tiny_session(&method).build_or_skip() else { return };
    let spec = session.stream().cloned().expect("stream=16 must configure churn");
    assert_eq!(spec.events_per_epoch(), 16);
    let r = session.run().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.reports.len(), 3);
    assert!(r.test_f1.is_finite());
    // invalidation re-uploads are charged per row through the tier
    assert_eq!(session.invalidated_bytes() % session.invalidated_rows().max(1), 0);
    // paranoid_validate ran every merged-graph batch through the block
    // validators, so reaching here means sampling stayed structurally
    // sound across three merges
}

// ---------------------------------------------------------------------------
// 3. byte-accounting ledger under churn (artifact-free)

#[test]
fn post_invalidation_byte_accounting_balances() {
    let ds = build_dataset("yelp-s", 0.05, 13);
    let row_bytes = ds.features.row_bytes() as u64;
    let shapes = BlockShapes::new(vec![64 * 24, 64 * 6, 64], vec![4, 5]);
    let reg = MethodRegistry::global();
    // refresh every epoch; a 5% degree-weighted cache keeps hot rows
    // resident so degree-proportional drops are near-certain to touch them
    let spec = reg.parse("gns:cache-fraction=0.05,policy=degree").unwrap();
    let ctx = BuildContext::new(&ds, shapes, 9);
    let mut s = reg.sampler(&spec, &ctx, 0).unwrap();
    let mut engine =
        TieringEngine::new(Box::new(SamplerPolicy), ds.graph.num_nodes(), row_bytes);
    let mut mem = gns::device::DeviceMemory::t4();
    let clock = LinkClock::pcie();
    let mut stats = TransferStats::default();

    let churn = StreamSpec::parse("500").unwrap().unwrap();
    let mut es = EdgeStream::new(churn, 3);
    let base: GraphView = Arc::new(ds.graph.clone());
    let mut graph = base.clone();
    let mut applied = DeltaOverlay::new();
    let mut pending = DeltaOverlay::new();

    let mut total_input_bytes = 0u64;
    let mut gen_upload_bytes = 0u64;
    let mut last_gen = 0u64;
    for epoch in 0..3 {
        // the trainer's epoch-boundary protocol: merge → repoint → invalidate
        if !pending.is_empty() {
            let touched = pending.touched_nodes();
            applied.absorb(&pending);
            pending = DeltaOverlay::new();
            graph = Arc::new(applied.merge(&base));
            graph.validate().unwrap();
            s.set_graph(graph.clone());
            engine.on_topology_delta(&touched, &clock, &mut stats);
        }
        s.begin_epoch(epoch);
        // uncached upload cost of each published generation, tracked the
        // same way the tiering identity tests do
        if s.cache_generation() != last_gen {
            gen_upload_bytes += s.cache_nodes().unwrap().len() as u64 * row_bytes;
            last_gen = s.cache_generation();
        }
        engine
            .begin_epoch(epoch, s.as_ref(), &mut mem, &clock, &mut stats)
            .unwrap();
        for i in 0..4 {
            let chunk = &ds.train[i * 64..(i + 1) * 64];
            let mb = s.sample_batch(chunk, &ds.labels).unwrap();
            total_input_bytes += mb.input_nodes.len() as u64 * row_bytes;
            engine.serve(&mb.input_nodes, &clock, &mut stats);
        }
        es.ingest_epoch(&graph, &mut pending);
    }
    let invalidation_bytes = engine.cache().invalidated_rows * row_bytes;
    assert!(
        engine.cache().invalidated_rows > 0,
        "500 degree-proportional events/epoch must touch the 5% hot tier"
    );
    // the full PCIe ledger: serve misses + delta uploads + invalidation
    // re-uploads, with both savings pools untouched by invalidation
    assert_eq!(
        stats.h2d_bytes,
        (total_input_bytes - stats.bytes_saved_by_cache)
            + (gen_upload_bytes - stats.bytes_saved_by_delta)
            + invalidation_bytes,
        "post-invalidation h2d must still balance against the savings pools"
    );
}

// ---------------------------------------------------------------------------
// 4. samplers stay valid across set_graph onto a merged CSR

#[test]
fn every_sampler_survives_set_graph_onto_merged_csr() {
    let ds = build_dataset("yelp-s", 0.05, 13);
    let shapes = BlockShapes::new(vec![32 * 24, 32 * 6, 32], vec![4, 5]);
    let reg = MethodRegistry::global();
    let n = ds.graph.num_nodes();

    // a merged view with real churn layered over the base graph
    let base: GraphView = Arc::new(ds.graph.clone());
    let mut overlay = DeltaOverlay::new();
    let mut es = EdgeStream::new(StreamSpec::parse("300").unwrap().unwrap(), 5);
    let churned = es.ingest_epoch(&base, &mut overlay);
    assert!(churned.inserted > 0 && churned.dropped > 0, "{churned:?}");
    assert!(!overlay.is_empty(), "300 events must leave an overlay");
    let merged: GraphView = Arc::new(overlay.merge(&base));
    merged.validate().unwrap();

    for method in METHODS {
        let spec = reg.parse(method).unwrap();
        let ctx = BuildContext::new(&ds, shapes.clone(), 11);
        let mut s = reg.sampler(&spec, &ctx, 0).unwrap();
        s.begin_epoch(0);
        s.sample_batch(&ds.train[..32], &ds.labels).unwrap();
        s.set_graph(merged.clone());
        s.begin_epoch(1);
        let mb = s.sample_batch(&ds.train[..32], &ds.labels).unwrap();
        assert!(!mb.input_nodes.is_empty(), "{method}");
        assert!(
            mb.input_nodes.iter().all(|&v| (v as usize) < n),
            "{method}: merged-graph batch escaped the node range"
        );
    }
}

// ---------------------------------------------------------------------------
// 5. spec plumbing

#[test]
fn every_method_accepts_the_stream_param() {
    let ds = build_dataset("yelp-s", 0.05, 13);
    let shapes = BlockShapes::new(vec![16 * 24, 16 * 6, 16], vec![4, 5]);
    let reg = MethodRegistry::global();
    let ctx = BuildContext::new(&ds, shapes, 3);
    for method in METHODS {
        for stream in ["off", "8", "8:grow=2:drop=1", "32:grow=0.5"] {
            let text = with_param(method, &format!("stream={stream}"));
            let spec = reg.parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            reg.factory(&spec, &ctx)
                .unwrap_or_else(|e| panic!("{text}: {e}"));
        }
    }
    // bad stream configs are rejected at factory build time
    let bad_specs =
        ["ns:stream=fast", "ns:stream=0", "ns:stream=4:grow=0:drop=0", "ns:stream=4:burst=2"];
    for bad in bad_specs {
        let spec = reg.parse(bad).unwrap();
        assert!(reg.factory(&spec, &ctx).is_err(), "{bad} should fail");
    }
}

#[test]
fn stream_param_round_trips_through_display_and_json() {
    let reg = MethodRegistry::global();
    for text in ["ns:stream=32:grow=2", "gns:cache-fraction=0.02,stream=8:grow=1.5:drop=0.5"] {
        let spec = reg.parse(text).unwrap();
        assert_eq!(spec.to_string(), text);
        assert_eq!(reg.parse(&spec.to_string()).unwrap(), spec);
        let j = spec.to_json().to_string_pretty();
        let parsed = gns::util::json::Json::parse(&j).unwrap();
        assert_eq!(reg.from_json(&parsed).unwrap(), spec);
    }
}
