//! Async-timeline overlap invariants (docs/TOPOLOGY.md §Overlap &
//! prefetch):
//!
//! 1. **prefetch=0 identity**: `prefetch=0` — and omitting `prefetch=`
//!    entirely, and the `SessionBuilder::prefetch(0)` override — yields
//!    bit-identical results on every `TransferStats` counter, every
//!    modeled stage second, and the per-epoch timeline (makespan + busy)
//!    for all four methods (the compatibility anchor of the overlap
//!    refactor; artifact-gated, skips when `make artifacts` has not run);
//! 2. **serial anchor**: with `prefetch=0` and `shards=1` the per-epoch
//!    makespan equals the serial sum of every reserved charge exactly;
//! 3. **overlap wins**: `prefetch>=1` under `topo=dist, shards=4`
//!    strictly reduces the modeled epoch wall time (makespan) while the
//!    per-link byte ledgers and per-lane busy seconds stay unchanged —
//!    overlap hides time, it never hides traffic; deeper prefetch never
//!    slows the pipeline;
//! 4. **crash-safe**: a run crashed by fault injection and resumed from
//!    its checkpoint reproduces the uninterrupted timeline bit-for-bit
//!    with `prefetch>0` (the busy-until state rides in the snapshot);
//! 5. the `prefetch=` param is plumbed through every method spec, bad
//!    depths are rejected at parse/build time, and the serving lane
//!    dispatches against the same timeline (`prefetch=0` keeps the exact
//!    legacy service times).

use std::path::PathBuf;
use std::time::Duration;

use gns::features::build_dataset;
use gns::sampling::spec::{prefetch_spec, BuildContext, MethodRegistry};
use gns::sampling::BlockShapes;
use gns::session::{Session, SessionBuilder};
use gns::topology::Lane;
use gns::util::timer::Stage;

const METHODS: [&str; 4] = ["ns", "ladies:s-layer=128", "lazygcn", "gns:cache-fraction=0.02"];

fn with_param(method: &str, param: &str) -> String {
    let sep = if method.contains(':') { "," } else { ":" };
    format!("{method}{sep}{param}")
}

/// The tiny-artifact session the e2e suites share.
fn tiny_session(method: &str) -> SessionBuilder {
    Session::builder("yelp-s", method)
        .scale(0.03)
        .seed(1)
        .epochs(2)
        .workers(1)
        .eval_batches(2)
        .artifact("tiny")
        .refit_features(true)
        .max_train_nodes(512)
        .max_val_nodes(128)
        .paranoid_validate(true)
}

/// Every deterministic transfer/time/timeline metric a run produces,
/// per epoch, in bit-exact form.
#[derive(Debug, PartialEq)]
struct OverlapMetrics {
    // (every TransferStats counter, as (bytes..., transfers..., nanos...))
    transfer_per_epoch: Vec<[u128; 10]>,
    // modeled seconds per pipeline stage, per epoch, in nanos
    stage_modeled_per_epoch: Vec<Vec<u128>>,
    // (makespan nanos, per-lane busy nanos) per epoch
    timeline_per_epoch: Vec<(u128, [u128; Lane::COUNT])>,
    test_f1: u64,
}

fn run_overlap_metrics(builder: SessionBuilder) -> Option<(OverlapMetrics, gns::session::RunResult)> {
    let mut session = builder.build_or_skip()?;
    let r = session.run().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let m = OverlapMetrics {
        transfer_per_epoch: r
            .reports
            .iter()
            .map(|rep| {
                let t = &rep.transfer;
                [
                    t.h2d_bytes as u128,
                    t.h2d_transfers as u128,
                    t.d2d_bytes as u128,
                    t.inter_bytes as u128,
                    t.inter_transfers as u128,
                    t.bytes_saved_by_cache as u128,
                    t.bytes_saved_by_delta as u128,
                    t.modeled_h2d.as_nanos(),
                    t.modeled_d2d.as_nanos(),
                    t.modeled_inter.as_nanos(),
                ]
            })
            .collect(),
        stage_modeled_per_epoch: r
            .reports
            .iter()
            .map(|rep| Stage::ALL.iter().map(|&s| rep.clock.modeled(s).as_nanos()).collect())
            .collect(),
        timeline_per_epoch: r
            .reports
            .iter()
            .map(|rep| {
                let mut busy = [0u128; Lane::COUNT];
                for (i, lane) in Lane::ALL.into_iter().enumerate() {
                    busy[i] = rep.timeline.busy_for(lane).as_nanos();
                }
                (rep.timeline.makespan.as_nanos(), busy)
            })
            .collect(),
        test_f1: r.test_f1.to_bits(),
    };
    Some((m, r))
}

// ---------------------------------------------------------------------------
// 1. prefetch=0 identity: bit-identical counters, stage seconds, timeline

#[test]
fn prefetch_zero_is_bit_identical_to_omitting_it_for_all_methods() {
    for method in METHODS {
        let Some((base, _)) = run_overlap_metrics(tiny_session(method)) else { return };
        let explicit =
            run_overlap_metrics(tiny_session(&with_param(method, "prefetch=0"))).unwrap().0;
        assert_eq!(explicit, base, "prefetch=0 diverged from default for {method}");
        // the builder override path must anchor identically too
        let via_builder = run_overlap_metrics(tiny_session(method).prefetch(0)).unwrap().0;
        assert_eq!(via_builder, base, "builder prefetch(0) diverged for {method}");
    }
}

#[test]
fn prefetch_zero_makespan_equals_serial_sum_unsharded() {
    // with prefetch=0 and a single device every reservation chains
    // back-to-back, so the critical path *is* the serial sum — exactly,
    // in integer nanos, per epoch and over the whole run
    for method in METHODS {
        let Some((m, r)) = run_overlap_metrics(tiny_session(method)) else { return };
        for (epoch, (makespan, busy)) in m.timeline_per_epoch.iter().enumerate() {
            let serial: u128 = busy.iter().sum();
            assert_eq!(
                *makespan, serial,
                "{method} epoch {epoch}: prefetch=0 makespan must equal the serial sum"
            );
        }
        let totals = r.timeline_totals();
        assert_eq!(totals.makespan, totals.serial_sum());
        assert_eq!(r.modeled_makespan_secs(), r.modeled_serial_secs());
        assert_eq!(totals.overlap_efficiency(), 0.0);
    }
}

// ---------------------------------------------------------------------------
// 3. overlap wins under dist + shards, without touching the ledgers

#[test]
fn prefetch_reduces_makespan_under_dist_shards_with_unchanged_ledgers() {
    // chunk_size(32) keeps several batches per shard lane (512 targets /
    // 4 shards / 32 ≈ 4) so every lane actually pipelines
    let method = with_param("gns:cache-fraction=0.02", "shards=4,topo=dist");
    let Some((serial, rs)) = run_overlap_metrics(tiny_session(&method).chunk_size(32)) else {
        return;
    };
    let (overlapped, ro) =
        run_overlap_metrics(tiny_session(&with_param(&method, "prefetch=2")).chunk_size(32))
            .unwrap();

    // traffic is invariant: every byte/transfer counter and modeled
    // per-link second is bit-identical under any prefetch depth
    assert_eq!(
        overlapped.transfer_per_epoch, serial.transfer_per_epoch,
        "prefetch must never change what is charged, only when it runs"
    );
    assert_eq!(overlapped.stage_modeled_per_epoch, serial.stage_modeled_per_epoch);
    assert_eq!(overlapped.test_f1, serial.test_f1, "prefetch must not touch training math");
    // per-lane busy seconds are invariant too — only the makespan moves
    for (k, (s, o)) in serial
        .timeline_per_epoch
        .iter()
        .zip(&overlapped.timeline_per_epoch)
        .enumerate()
    {
        assert_eq!(o.1, s.1, "epoch {k}: busy seconds changed under prefetch");
        assert!(
            o.0 <= s.0,
            "epoch {k}: prefetch=2 makespan {} > serial {}",
            o.0,
            s.0
        );
    }
    // ...and over the run it strictly shrinks: dist charges real h2d +
    // inter seconds every epoch, so there is always something to hide
    assert!(
        ro.modeled_makespan_secs() < rs.modeled_makespan_secs(),
        "prefetch=2 must strictly reduce the modeled epoch wall time \
         ({} !< {})",
        ro.modeled_makespan_secs(),
        rs.modeled_makespan_secs()
    );
    assert!(ro.timeline_totals().overlap_efficiency() > 0.0);

    // deeper prefetch never slows the pipeline
    let (_, r4) =
        run_overlap_metrics(tiny_session(&with_param(&method, "prefetch=4")).chunk_size(32))
            .unwrap();
    assert!(r4.modeled_makespan_secs() <= ro.modeled_makespan_secs() + 1e-12);
}

// ---------------------------------------------------------------------------
// 3b. the modeled sampling lane (docs/TOPOLOGY.md §Overlap & prefetch)

#[test]
fn sample_lane_serial_at_prefetch_zero_and_hidden_at_prefetch_one() {
    // `SessionBuilder::sample_lane(true)` reserves each batch's measured
    // sample_time / workers on `Lane::Sample` ahead of its transfer
    // chain. The sample charge is *measured* (wall-clock), so timelines
    // are not bit-comparable across runs — but the byte ledgers and
    // modeled stage seconds stay deterministic, and the structural
    // invariants hold exactly within each run.
    let method = with_param("gns:cache-fraction=0.02", "topo=dist");
    let Some((serial, rs)) =
        run_overlap_metrics(tiny_session(&method).chunk_size(32).sample_lane(true))
    else {
        return;
    };
    let sample_idx = Lane::Sample.index();
    for (epoch, (makespan, busy)) in serial.timeline_per_epoch.iter().enumerate() {
        // prefetch=0: the sample charge chains like everything else, so
        // the makespan is still exactly the serial sum — now including
        // the (non-zero) sample lane — in integer nanos
        assert!(busy[sample_idx] > 0, "epoch {epoch}: sample lane carried no charge");
        assert_eq!(
            *makespan,
            busy.iter().sum::<u128>(),
            "epoch {epoch}: sample-lane prefetch=0 makespan must equal the serial sum"
        );
    }

    // prefetch=1 hides sampling (and transfers) under the previous
    // batch's compute: strictly smaller modeled wall time, while every
    // byte/transfer counter, modeled stage second, and the training
    // math are unchanged
    let (overlapped, ro) = run_overlap_metrics(
        tiny_session(&with_param(&method, "prefetch=1")).chunk_size(32).sample_lane(true),
    )
    .unwrap();
    assert_eq!(overlapped.transfer_per_epoch, serial.transfer_per_epoch);
    assert_eq!(overlapped.stage_modeled_per_epoch, serial.stage_modeled_per_epoch);
    assert_eq!(overlapped.test_f1, serial.test_f1);
    assert!(
        ro.modeled_makespan_secs() < rs.modeled_makespan_secs(),
        "sample lane + prefetch=1 must strictly reduce the modeled wall time ({} !< {})",
        ro.modeled_makespan_secs(),
        rs.modeled_makespan_secs()
    );

    // with the lane off (the default) nothing is ever reserved on it
    let (off, _) = run_overlap_metrics(tiny_session(&method).chunk_size(32)).unwrap();
    for (epoch, (_, busy)) in off.timeline_per_epoch.iter().enumerate() {
        assert_eq!(busy[sample_idx], 0, "epoch {epoch}: sample lane busy without opt-in");
    }
}

// ---------------------------------------------------------------------------
// 4. crash-safe: the timeline rides in the snapshot

#[test]
fn resume_with_prefetch_reproduces_the_timeline_bit_identical() {
    let dir: PathBuf =
        std::env::temp_dir().join(format!("gns-ckpt-overlap-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let method = with_param("gns:cache-fraction=0.02", "topo=dist,prefetch=2");
    let Some((base, _)) = run_overlap_metrics(tiny_session(&method).epochs(3)) else { return };

    let ckpt = format!("ckpt=every=1:dir={}", dir.display());
    let crashed = with_param(&with_param(&method, &ckpt), "faults=crash@epoch=2");
    let mut session = tiny_session(&crashed).epochs(3).build_or_skip().unwrap();
    let r = session.run().unwrap();
    assert!(r.error.expect("fault-injected run should crash").contains("injected crash"));

    let (resumed, _) =
        run_overlap_metrics(tiny_session(&with_param(&method, &ckpt)).epochs(3)).unwrap();
    assert_eq!(resumed, base, "resumed timeline diverged from uninterrupted");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// 5. spec plumbing + serving

#[test]
fn every_method_accepts_the_prefetch_param() {
    let ds = build_dataset("yelp-s", 0.05, 13);
    let shapes = BlockShapes::new(vec![16 * 24, 16 * 6, 16], vec![4, 5]);
    let reg = MethodRegistry::global();
    let ctx = BuildContext::new(&ds, shapes, 3);
    for method in METHODS {
        for k in [0usize, 1, 2, 4] {
            let text = with_param(method, &format!("prefetch={k}"));
            let spec = reg.parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(prefetch_spec(&spec).unwrap(), k, "{text}");
            reg.factory(&spec, &ctx).unwrap_or_else(|e| panic!("{text}: {e}"));
        }
    }
    // omitting the param means a serial schedule
    assert_eq!(prefetch_spec(&reg.parse("ns").unwrap()).unwrap(), 0);
    // bad depths are rejected at parse time (prefetch= is a typed Int)
    for bad in ["ns:prefetch=deep", "ns:prefetch=-1", "ns:prefetch=1.5"] {
        assert!(reg.parse(bad).is_err(), "{bad} should fail to parse");
    }
}

#[test]
fn prefetch_param_round_trips_through_display_and_json() {
    let reg = MethodRegistry::global();
    for text in [
        "ns:prefetch=2",
        "gns:cache-fraction=0.02,prefetch=4,topo=dist",
        "lazygcn:prefetch=1,shards=2",
    ] {
        let spec = reg.parse(text).unwrap();
        assert_eq!(reg.parse(&spec.to_string()).unwrap(), spec);
        let j = spec.to_json().to_string_pretty();
        let parsed = gns::util::json::Json::parse(&j).unwrap();
        assert_eq!(reg.from_json(&parsed).unwrap(), spec);
    }
}

#[test]
fn serving_lane_dispatches_against_the_timeline() {
    // prefetch=0 keeps the exact legacy service-time accounting
    let serve = "serve=200:requests=40";
    let Some(mut base) = tiny_session(&with_param("ns", serve)).build_or_skip() else {
        return;
    };
    base.run().unwrap();
    let b = base.serve().unwrap();

    let mut same =
        tiny_session(&with_param(&with_param("ns", serve), "prefetch=0")).build_or_skip().unwrap();
    same.run().unwrap();
    let s = same.serve().unwrap();
    assert_eq!(s.latency.p50.to_bits(), b.latency.p50.to_bits());
    assert_eq!(s.latency.p99.to_bits(), b.latency.p99.to_bits());
    assert_eq!(s.latency.mean.to_bits(), b.latency.mean.to_bits());
    assert_eq!(s.transfer.h2d_bytes, b.transfer.h2d_bytes);

    // prefetch>0 dispatches the same requests against the overlapped
    // timeline: identical traffic, finite latencies, and the modeled
    // service seconds can only shrink (transfers hide under compute)
    let mut deep =
        tiny_session(&with_param(&with_param("ns", serve), "prefetch=2")).build_or_skip().unwrap();
    deep.run().unwrap();
    let d = deep.serve().unwrap();
    assert_eq!(d.requests, b.requests);
    assert_eq!(d.transfer.h2d_bytes, b.transfer.h2d_bytes);
    assert!(d.latency.mean.is_finite() && d.latency.mean >= 0.0);
    assert!(
        d.latency.mean <= b.latency.mean + 1e-9,
        "overlap must not slow serving: {} > {}",
        d.latency.mean,
        b.latency.mean
    );
}

// ---------------------------------------------------------------------------
// timeline algebra at the session boundary (artifact-free)

#[test]
fn timeline_stats_merge_is_additive() {
    use gns::topology::{Timeline, TimelineStats};
    let mut t = Timeline::default();
    t.reserve(Lane::H2d, Duration::ZERO, Duration::from_millis(3));
    t.reserve(Lane::Compute, Duration::ZERO, Duration::from_millis(5));
    let a = t.stats_since(&Timeline::default());
    let mut merged = TimelineStats::default();
    merged.merge(&a);
    merged.merge(&a);
    assert_eq!(merged.busy_for(Lane::H2d), Duration::from_millis(6));
    assert_eq!(merged.serial_sum(), a.serial_sum() * 2);
}
