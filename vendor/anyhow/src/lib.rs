//! Offline shim of the `anyhow` API surface this workspace uses.
//!
//! The build environment has no crates.io access, so this crate provides
//! the subset of anyhow the coordinator relies on: `Error` with a context
//! chain, the `Context` extension trait for `Result`, the `anyhow!` /
//! `bail!` / `ensure!` macros, and `{:#}` chain formatting. Errors are
//! stored as rendered strings (no downcasting — nothing in the workspace
//! downcasts).

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context messages. `chain[0]` is the most
/// recently attached context; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from any std error, capturing its source chain.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Build from a displayable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach a higher-level context message.
    pub fn context<C: fmt::Display + Send + Sync + 'static>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message followed by each cause, top-down.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, matching anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod ext {
    /// Sealed conversion helper so `Context` works both for std errors and
    /// for `anyhow::Error` itself (which deliberately does not implement
    /// `std::error::Error`, exactly like the real crate).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Context-attaching extension for `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $msg))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chain_renders_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading artifact")
            .unwrap_err()
            .context("running experiment");
        assert_eq!(format!("{e}"), "running experiment");
        let full = format!("{e:#}");
        assert!(full.contains("running experiment: loading artifact: missing thing"), "{full}");
    }

    #[test]
    fn macros_build_errors() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("n too big"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f(n: usize) -> Result<()> {
            ensure!(n > 0);
            Ok(())
        }
        assert!(f(0).unwrap_err().to_string().contains("n > 0"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
