//! Compile-only stub of the `xla` PJRT bindings the runtime layer links
//! against.
//!
//! The real crate (xla_extension 0.5.1 bindings) is not installable in the
//! offline build environment, so this stub mirrors the exact API surface
//! `gns::runtime` uses and fails *at runtime* with a clear diagnostic the
//! moment a PJRT client is requested. Everything that does not require the
//! PJRT runtime (graph store, samplers, pipeline, spec/session layers,
//! experiments that skip on missing artifacts) works unchanged.
//!
//! To execute AOT artifacts, replace this path dependency in the root
//! Cargo.toml with the real `xla` crate — the signatures below are the
//! contract.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "xla PJRT runtime unavailable: built against the vendor/xla stub \
             (swap in the real xla crate to execute AOT artifacts)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Host literal: the stub keeps real data so host-only paths (state
/// initialisation, memcheck's literal churn loop) behave sensibly.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: FromLiteralElem>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }
}

/// Element conversion for `Literal::to_vec` (the runtime reads f32 only;
/// the trait keeps the call sites' turbofish form compiling).
pub trait FromLiteralElem {
    fn from_f32(x: f32) -> Self;
}

impl FromLiteralElem for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_and_reshape_guard() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
