#!/usr/bin/env python3
"""Advisory bench-trend diff for CI (docs/PERF.md).

Usage: bench_trend.py <prev-dir> <new-dir>

Compares every BENCH_*.json in <new-dir> against the file of the same
name in <prev-dir> (the previous successful CI run's artifact). Metrics
whose direction is known and which regressed by more than THRESHOLD are
surfaced as GitHub `::warning::` annotations.

Deliberately advisory: bench smokes run on shared CI runners, so noise
is expected — this script NEVER fails the build (always exits 0). It is
schema-aware: when a file's `schema_version` changed between runs the
comparison for that file is skipped instead of warning on renamed or
re-scaled metrics.

Pairing: documents are flattened to `path -> number`, with array
elements paired by index — every bench emits its config sweep in a
deterministic order, so index identity is stable across runs. Keys with
no direction entry (config echoes, counts, timestamps) are ignored.
"""

import json
import sys
from pathlib import Path

THRESHOLD = 0.10  # warn when a metric moves >10% in the bad direction

# metric direction by leaf key: False = lower is better, True = higher.
# makespan_secs / serial_secs are covered by the _secs suffix (lower is
# better), so a shrinking makespan is an improvement, never a regression;
# overlap_efficiency is the inverse view of the same ratio and is
# higher-better. BENCH_stream.json's invalidation_bytes / merge_ms ride
# the lower-better suffixes; its throughput rates are listed explicitly.
LOWER_SUFFIXES = ("_ms", "_secs", "_bytes", "_us")
LOWER_KEYS = {"ns_per_batch", "ns_per_iter"}
HIGHER_KEYS = {
    "hit_rate",
    "throughput_rps",
    "local_fraction",
    "overlap_efficiency",
    "batches_per_sec",
    "lane_parallel_speedup",
    "merge_edges_per_sec",
    "save_mb_per_s",
}
# config echoes that match a lower-better suffix but are not metrics;
# inserted/dropped/final_edges/rate are unsuffixed and skip by default
IGNORED_KEYS = {"max_wait_us", "unix_time", "schema_version"}


def direction(key):
    """True = higher is better, False = lower is better, None = skip."""
    if key in IGNORED_KEYS:
        return None
    if key in HIGHER_KEYS:
        return True
    if key in LOWER_KEYS or key.endswith(LOWER_SUFFIXES):
        return False
    return None


def flatten(value, path, out):
    if isinstance(value, dict):
        for k, v in value.items():
            flatten(v, path + [k], out)
    elif isinstance(value, list):
        for i, v in enumerate(value):
            flatten(v, path + [str(i)], out)
    elif isinstance(value, (int, float)) and not isinstance(value, bool):
        out["/".join(path)] = float(value)


def compare(name, prev_doc, new_doc):
    if prev_doc.get("schema_version") != new_doc.get("schema_version"):
        print(
            f"{name}: schema_version changed "
            f"({prev_doc.get('schema_version')} -> {new_doc.get('schema_version')}), "
            "skipping trend diff"
        )
        return 0
    prev, new = {}, {}
    flatten(prev_doc, [], prev)
    flatten(new_doc, [], new)
    regressions = 0
    for path, new_val in sorted(new.items()):
        key = path.rsplit("/", 1)[-1]
        higher_is_better = direction(key)
        if higher_is_better is None or path not in prev:
            continue
        prev_val = prev[path]
        if prev_val == 0.0:
            continue  # no baseline to express a ratio against
        change = (new_val - prev_val) / abs(prev_val)
        regressed = change < -THRESHOLD if higher_is_better else change > THRESHOLD
        if regressed:
            regressions += 1
            print(
                f"::warning title=bench trend ({name})::{path}: "
                f"{prev_val:.6g} -> {new_val:.6g} "
                f"({change:+.1%}, {'higher' if higher_is_better else 'lower'} is better)"
            )
    return regressions


def main():
    if len(sys.argv) != 3:
        print(f"usage: {sys.argv[0]} <prev-dir> <new-dir>")
        return
    prev_dir, new_dir = Path(sys.argv[1]), Path(sys.argv[2])
    total = 0
    compared = 0
    for new_path in sorted(new_dir.glob("BENCH_*.json")):
        prev_path = prev_dir / new_path.name
        if not prev_path.exists():
            print(f"{new_path.name}: no previous artifact, skipping")
            continue
        try:
            prev_doc = json.loads(prev_path.read_text())
            new_doc = json.loads(new_path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"{new_path.name}: unreadable ({e}), skipping")
            continue
        compared += 1
        total += compare(new_path.name, prev_doc, new_doc)
    print(f"bench trend: {compared} file(s) compared, {total} metric(s) regressed >10%")
    # advisory only — never fail the build on bench noise


if __name__ == "__main__":
    main()
