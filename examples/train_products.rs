//! End-to-end validation driver (DESIGN.md §6): train 3-layer GraphSAGE on
//! the OGBN-products analogue with both NS and GNS, long enough for real
//! convergence, and report the loss/F1 curves plus the paper's headline
//! comparisons (input-node reduction, transfer savings, epoch speedup).
//!
//!   cargo run --release --offline --example train_products -- \
//!       [--scale 1.0] [--epochs 8] [--workers 1]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use gns::experiments::harness::{check_exp_args, run_method, ExpOptions};
use gns::sampling::spec::{MethodRegistry, MethodSpec};
use gns::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    check_exp_args(&args, &[]).map_err(anyhow::Error::msg)?;
    // honor every shared experiment flag; this driver's own defaults
    // (full scale, long run) apply only when the flag is absent
    let mut opts = ExpOptions::from_args(&args);
    opts.scale = args.f64_or("scale", 1.0);
    opts.epochs = args.usize_or("epochs", 8);
    opts.seed = args.u64_or("seed", 3);
    opts.eval_batches = args.usize_or("eval-batches", 8);
    println!(
        "=== end-to-end: products-s x{} | {} epochs | batch 256 | fanouts 5,10,15 ===\n",
        opts.scale, opts.epochs
    );

    let registry = MethodRegistry::global();
    let mut summary: Vec<(String, f64, f64, f64)> = Vec::new();
    for spec in [MethodSpec::new("ns"), MethodSpec::new("gns")] {
        let label = registry.label(&spec);
        println!("--- {label} ---");
        let r = run_method("products-s", &spec, &opts)?;
        if let Some(e) = &r.error {
            anyhow::bail!("{label} failed: {e}");
        }
        for rep in &r.reports {
            println!(
                "epoch {:>2}: loss {:.4}  train-acc {:.3}  val-F1 {:.3}  wall {:>6.2}s  device-frame {:>7.3}s  inputs {:.0} cached {:.0}",
                rep.epoch,
                rep.mean_loss,
                rep.train_acc,
                rep.val_f1,
                rep.wall.as_secs_f64(),
                rep.device_frame_secs(),
                rep.avg_input_nodes,
                rep.avg_cached_inputs,
            );
        }
        println!("test F1: {:.4}", r.test_f1);
        let last = r.reports.last().unwrap();
        println!(
            "transfer/epoch: h2d {}  saved-by-cache {}\n",
            gns::util::fmt_bytes(last.transfer.h2d_bytes),
            gns::util::fmt_bytes(last.transfer.bytes_saved_by_cache),
        );
        summary.push((label, r.test_f1, r.epoch_time(), last.avg_input_nodes));
    }

    println!("=== summary (paper Table 3/4 shape) ===");
    println!(
        "{:<8} {:>8} {:>18} {:>14}",
        "method", "F1", "epoch (device-s)", "inputs/batch"
    );
    for (label, f1, t, inputs) in &summary {
        println!("{label:<8} {:>8.4} {:>18.3} {:>14.0}", f1, t, inputs);
    }
    if summary.len() == 2 {
        let speedup = summary[0].2 / summary[1].2;
        let reduction = summary[0].3 / summary[1].3;
        println!(
            "\nGNS vs NS: {speedup:.2}x faster epochs (device frame), {reduction:.1}x fewer input nodes, F1 delta {:+.4}",
            summary[1].1 - summary[0].1
        );
    }
    Ok(())
}
