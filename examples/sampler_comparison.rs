//! Sampler zoo comparison: run all four methods on one dataset and print a
//! side-by-side of accuracy, epoch time (both frames), mini-batch shape
//! statistics, and failure modes.
//!
//!   cargo run --release --offline --example sampler_comparison -- \
//!       [--dataset products-s] [--scale 0.4] [--epochs 3]

use gns::experiments::harness::{check_exp_args, run_method, ExpOptions};
use gns::experiments::table3;
use gns::sampling::spec::MethodRegistry;
use gns::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    check_exp_args(&args, &["dataset"]).map_err(anyhow::Error::msg)?;
    let dataset = args.str_or("dataset", "products-s").to_string();
    // honor every shared experiment flag; comparison-specific defaults
    // apply only when the flag is absent
    let mut opts = ExpOptions::from_args(&args);
    opts.scale = args.f64_or("scale", 0.4);
    opts.seed = args.u64_or("seed", 5);
    let registry = MethodRegistry::global();
    let methods = table3::methods();
    println!(
        "comparing {} methods on {dataset} (x{}, {} epochs)\n",
        methods.len(),
        opts.scale,
        opts.epochs
    );
    println!(
        "{:<13} {:>7} {:>12} {:>10} {:>13} {:>10} {:>9}",
        "method", "F1", "device-s/ep", "wall-s/ep", "inputs/batch", "isolated", "note"
    );
    for m in methods {
        let r = run_method(&dataset, &m, &opts)?;
        let (inputs, isolated) = r
            .reports
            .last()
            .map(|rep| (rep.avg_input_nodes, rep.isolated_nodes))
            .unwrap_or((f64::NAN, 0));
        let note = r
            .error
            .as_deref()
            .map(|e| if e.contains("OOM") { "OOM" } else { "error" })
            .unwrap_or("");
        println!(
            "{:<13} {:>7.4} {:>12.3} {:>10.2} {:>13.0} {:>10} {:>9}",
            registry.label(&m),
            r.test_f1,
            r.epoch_time(),
            r.wall_epoch_time(),
            inputs,
            isolated,
            note
        );
    }
    println!(
        "\n(device-s = modeled T4 frame: copy @PCIe + compute @1.6 TFLOP/s;\n\
         wall-s = measured on this CPU testbed. Both per epoch.)"
    );
    Ok(())
}
