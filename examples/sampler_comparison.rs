//! Sampler zoo comparison: run all four methods on one dataset and print a
//! side-by-side of accuracy, epoch time (both frames), mini-batch shape
//! statistics, and failure modes.
//!
//!   cargo run --release --offline --example sampler_comparison -- \
//!       [--dataset products-s] [--scale 0.4] [--epochs 3]

use gns::experiments::harness::{run_method, ExpOptions, Method};
use gns::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let dataset = args.str_or("dataset", "products-s").to_string();
    let opts = ExpOptions {
        scale: args.f64_or("scale", 0.4),
        epochs: args.usize_or("epochs", 3),
        seed: args.u64_or("seed", 5),
        ..Default::default()
    };
    let methods = vec![
        Method::Ns,
        Method::Ladies(512),
        Method::Ladies(5000),
        Method::LazyGcn,
        Method::gns_default(opts.seed),
    ];
    println!(
        "comparing {} methods on {dataset} (x{}, {} epochs)\n",
        methods.len(),
        opts.scale,
        opts.epochs
    );
    println!(
        "{:<13} {:>7} {:>12} {:>10} {:>13} {:>10} {:>9}",
        "method", "F1", "device-s/ep", "wall-s/ep", "inputs/batch", "isolated", "note"
    );
    for m in methods {
        let r = run_method(&dataset, &m, &opts)?;
        let (inputs, isolated) = r
            .reports
            .last()
            .map(|rep| (rep.avg_input_nodes, rep.isolated_nodes))
            .unwrap_or((f64::NAN, 0));
        let note = r
            .error
            .as_deref()
            .map(|e| if e.contains("OOM") { "OOM" } else { "error" })
            .unwrap_or("");
        println!(
            "{:<13} {:>7.4} {:>12.3} {:>10.2} {:>13.0} {:>10} {:>9}",
            m.label(),
            r.test_f1,
            r.epoch_time(),
            r.wall_epoch_time(),
            inputs,
            isolated,
            note
        );
    }
    println!(
        "\n(device-s = modeled T4 frame: copy @PCIe + compute @1.6 TFLOP/s;\n\
         wall-s = measured on this CPU testbed. Both per epoch.)"
    );
    Ok(())
}
