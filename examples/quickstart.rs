//! Quickstart: the 30-second tour of the library.
//!
//!   make artifacts && cargo run --release --offline --example quickstart
//!
//! One `Session` wraps the whole run: the method spec is parsed by the
//! `MethodRegistry`, the dataset analogue is generated and refitted to
//! the `tiny` AOT artifact, and `run()` trains GraphSAGE with Global
//! Neighbor Sampling and evaluates the test split. The spec shows all
//! four cross-cutting parameters together: `cache=` (feature tier,
//! docs/TIERING.md), `shards=` (partitioned pipelines, docs/SHARDING.md
//! — `part=greedy` is the locality-aware streaming partitioner),
//! `topo=` (modeled hardware topology, docs/TOPOLOGY.md — `dist`
//! charges cross-shard fetches IB seconds), and `serve=` (the online
//! inference lane, docs/SERVING.md — after training, an open-loop
//! request stream is micro-batched through the same hot path).

use gns::session::Session;

fn main() -> anyhow::Result<()> {
    let mut session = Session::builder(
        "yelp-s",
        "gns:cache-fraction=0.02,cache=auto,shards=2:part=greedy,topo=dist,\
         serve=2000:max-batch=32:requests=256",
    )
        .scale(0.05)
        .seed(7)
        .epochs(4)
        .artifact("tiny") // the smoke artifact from `make artifacts`
        .refit_features(true) // resynthesize features at its dims
        .build()?;
    println!(
        "artifact 'tiny': {} layers, batch {}, levels {:?}",
        session.meta().num_layers,
        session.meta().batch_size,
        session.meta().level_sizes
    );
    println!("dataset: {}", session.dataset().graph.stats());

    let result = session.run()?;
    if let Some(e) = &result.error {
        anyhow::bail!("training failed: {e}");
    }
    for r in &result.reports {
        println!(
            "epoch {}: loss {:.4}  val-F1 {:.3}  inputs/batch {:.0} (cached {:.0})",
            r.epoch, r.mean_loss, r.val_f1, r.avg_input_nodes, r.avg_cached_inputs
        );
    }
    println!("test F1: {:.4}", result.test_f1);

    let last = result.reports.last().unwrap();
    println!(
        "\nGNS cache saved {} of CPU→GPU transfer this epoch (h2d {}, d2d {}).",
        gns::util::fmt_bytes(last.transfer.bytes_saved_by_cache),
        gns::util::fmt_bytes(last.transfer.h2d_bytes),
        gns::util::fmt_bytes(last.transfer.d2d_bytes),
    );
    println!(
        "{} shards exchanged {} remotely — {:.4}s modeled on the {} interconnect.",
        session.num_shards(),
        gns::util::fmt_bytes(result.cross_shard_bytes()),
        result.modeled_inter_secs(),
        session.topology().name,
    );
    println!("{}", last.clock.render("stage breakdown (last epoch)"));

    // the serving lane configured by `serve=`: 2000 req/s offered load,
    // admission-queued micro-batches over the recycled hot path
    let report = session.serve()?;
    print!("\n{}", report.render());
    Ok(())
}
