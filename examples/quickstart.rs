//! Quickstart: the 30-second tour of the library.
//!
//!   make artifacts && cargo run --release --offline --example quickstart
//!
//! Generates a small power-law graph with learnable labels, trains a
//! 2-layer GraphSAGE for a few epochs with Global Neighbor Sampling, and
//! prints the loss/F1 trajectory plus the data-movement savings the GNS
//! cache produced.

use gns::features::{build_dataset, synthesize_features, FeatureParams};
use gns::graph::generate::LabeledGraph;
use gns::pipeline::{TrainOptions, Trainer};
use gns::runtime::Runtime;
use gns::sampling::gns::{GnsConfig, GnsSampler};
use gns::sampling::Sampler;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. The AOT artifact: a JAX GraphSAGE train step (with the Pallas
    //    aggregation kernel inside) lowered to HLO text at build time.
    let rt = Runtime::load_by_name("tiny")?;
    println!(
        "artifact 'tiny': {} layers, batch {}, levels {:?}",
        rt.meta.num_layers, rt.meta.batch_size, rt.meta.level_sizes
    );

    // 2. A synthetic dataset analogue, re-featured to the artifact dims.
    let mut ds = build_dataset("yelp-s", 0.05, 7);
    let lg = LabeledGraph {
        graph: ds.graph.clone(),
        labels: ds.labels.iter().map(|&c| (c as usize % rt.meta.num_classes) as u16).collect(),
        num_classes: rt.meta.num_classes,
    };
    ds.features = synthesize_features(
        &lg,
        &FeatureParams { dim: rt.meta.feature_dim, seed: 7, ..Default::default() },
    );
    ds.labels = lg.labels;
    ds.num_classes = rt.meta.num_classes;
    println!("dataset: {}", ds.graph.stats());

    // 3. Train with GNS: a 2% cache, refreshed every epoch.
    let shapes = rt.meta.block_shapes();
    let graph = Arc::new(ds.graph.clone());
    let template = GnsSampler::new(
        graph,
        shapes,
        &ds.train,
        GnsConfig { cache_fraction: 0.02, seed: 7, ..Default::default() },
    );
    let opts = TrainOptions { epochs: 4, ..Default::default() };
    let mut trainer = Trainer::new(rt, &ds, &opts)?;
    let reports = trainer.train(
        &|w| Box::new(template.instance(w as u64, w == 0)) as Box<dyn Sampler>,
        &opts,
    )?;

    for r in &reports {
        println!(
            "epoch {}: loss {:.4}  val-F1 {:.3}  inputs/batch {:.0} (cached {:.0})",
            r.epoch, r.mean_loss, r.val_f1, r.avg_input_nodes, r.avg_cached_inputs
        );
    }
    let last = reports.last().unwrap();
    println!(
        "\nGNS cache saved {} of CPU→GPU transfer this epoch (h2d {}, d2d {}).",
        gns::util::fmt_bytes(last.transfer.bytes_saved_by_cache),
        gns::util::fmt_bytes(last.transfer.h2d_bytes),
        gns::util::fmt_bytes(last.transfer.d2d_bytes),
    );
    println!("{}", last.clock.render("stage breakdown (last epoch)"));
    Ok(())
}
