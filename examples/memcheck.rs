//! Memory-regression check: RSS must stay flat across train steps.
//!
//! This caught a real bug: the xla crate's `execute(&[Literal])` leaks
//! every input device buffer (see runtime/mod.rs). Run both modes:
//!
//!   cargo run --release --example memcheck lit    # literal create/drop
//!   cargo run --release --example memcheck step   # train-step loop
//!
//! RSS is printed every 15 iterations; growth ⇒ regression.

use gns::sampling::spec::{BuildContext, MethodRegistry, MethodSpec};

fn main() -> anyhow::Result<()> {
    let mode = std::env::args().nth(1).unwrap_or("lit".into());
    let rss = || {
        let s = std::fs::read_to_string("/proc/self/status").unwrap();
        s.lines().find(|l| l.starts_with("VmRSS")).unwrap().to_string()
    };
    if mode == "lit" {
        // literal create/drop loop: 200 x 5MB
        for i in 0..200 {
            let v = vec![0.5f32; 20000 * 64];
            let lit = xla::Literal::vec1(&v).reshape(&[20000, 64])?;
            std::hint::black_box(&lit);
            if i % 50 == 0 {
                println!("{i}: {}", rss());
            }
        }
        println!("end: {}", rss());
    } else {
        let rt = gns::runtime::Runtime::load_by_name("yelp")?;
        let ds = gns::features::build_dataset("yelp-s", 0.4, 1);
        let shapes = rt.meta.block_shapes();
        let ctx = BuildContext::new(&ds, shapes, 1);
        let mut ns = MethodRegistry::global().sampler(&MethodSpec::new("ns"), &ctx, 0)?;
        let mut state = rt.init_state(1);
        let mut x0 = vec![0f32; rt.meta.level_sizes[0] * rt.meta.feature_dim];
        let mb = ns.sample_batch(&ds.train[..256], &ds.labels)?;
        let dim = ds.features.dim();
        ds.features
            .slice_into(&mb.input_nodes, &mut x0[..mb.input_nodes.len() * dim]);
        for i in 0..60 {
            rt.train_step(&mut state, &mb, &x0, 3e-3)?;
            if i % 15 == 0 {
                println!("{i}: {}", rss());
            }
        }
        println!("end: {}", rss());
    }
    Ok(())
}
