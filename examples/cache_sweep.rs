//! GNS cache tuning: sweep cache size × update period × policy and print
//! accuracy, cache coverage, and transfer savings — the operational guide
//! for deploying GNS (extends the paper's Table 6 with the policy axis).
//!
//!   cargo run --release --offline --example cache_sweep -- \
//!       [--dataset products-s] [--scale 0.3] [--epochs 4]
//!
//! Every cell is a method spec (`gns:cache-fraction=F,update-period=P,
//! policy=X`) run through the shared harness — the sweep is just spec
//! construction.

use gns::experiments::harness::{check_exp_args, run_method, ExpOptions};
use gns::sampling::spec::MethodSpec;
use gns::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    check_exp_args(&args, &["dataset"]).map_err(anyhow::Error::msg)?;
    let dataset = args.str_or("dataset", "products-s").to_string();
    // honor every shared experiment flag; sweep-specific defaults apply
    // only when the flag is absent
    let mut opts = ExpOptions::from_args(&args);
    opts.epochs = args.usize_or("epochs", 4);
    opts.seed = args.u64_or("seed", 9);
    println!(
        "GNS cache sweep on {dataset} (x{}, {} epochs)\n",
        opts.scale, opts.epochs
    );
    println!(
        "{:<12} {:>7} {:>8} {:>8} {:>14} {:>14}",
        "policy", "cache%", "period", "F1", "cached/batch", "saved/epoch"
    );
    for policy in ["degree", "random-walk", "uniform"] {
        for &frac in &[0.01, 0.001] {
            for &period in &[1usize, 5] {
                let spec = MethodSpec::new("gns")
                    .with("cache-fraction", frac)
                    .with("update-period", period)
                    .with("policy", policy);
                let r = run_method(&dataset, &spec, &opts)?;
                let (cached, saved) = r
                    .reports
                    .last()
                    .map(|rep| (rep.avg_cached_inputs, rep.transfer.bytes_saved_by_cache))
                    .unwrap_or((f64::NAN, 0));
                println!(
                    "{:<12} {:>7.2} {:>8} {:>8.4} {:>14.0} {:>14}",
                    policy,
                    100.0 * frac,
                    period,
                    r.test_f1,
                    cached,
                    gns::util::fmt_bytes(saved)
                );
            }
        }
    }
    println!(
        "\nReading: degree policy should dominate uniform; random-walk wins\n\
         when the train split is small. Larger caches + shorter periods give\n\
         more cached inputs; accuracy should be flat at 1% (paper Table 6)."
    );
    Ok(())
}
