//! GNS cache tuning: sweep cache size × update period × policy and print
//! accuracy, cache coverage, and transfer savings — the operational guide
//! for deploying GNS (extends the paper's Table 6 with the policy axis).
//!
//!   cargo run --release --offline --example cache_sweep -- \
//!       [--dataset products-s] [--scale 0.3] [--epochs 4]

use gns::experiments::harness::{run_method, ExpOptions, Method};
use gns::sampling::gns::{CachePolicy, GnsConfig};
use gns::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let dataset = args.str_or("dataset", "products-s").to_string();
    let opts = ExpOptions {
        scale: args.f64_or("scale", 0.3),
        epochs: args.usize_or("epochs", 4),
        seed: args.u64_or("seed", 9),
        ..Default::default()
    };
    println!(
        "GNS cache sweep on {dataset} (x{}, {} epochs)\n",
        opts.scale, opts.epochs
    );
    println!(
        "{:<12} {:>7} {:>8} {:>8} {:>14} {:>14}",
        "policy", "cache%", "period", "F1", "cached/batch", "saved/epoch"
    );
    for policy in [
        CachePolicy::Degree,
        CachePolicy::RandomWalk { fanouts: vec![5, 10, 15] },
        CachePolicy::Uniform,
    ] {
        for &frac in &[0.01, 0.001] {
            for &period in &[1usize, 5] {
                let m = Method::Gns(GnsConfig {
                    cache_fraction: frac,
                    update_period: period,
                    policy: policy.clone(),
                    seed: opts.seed,
                    ..Default::default()
                });
                let r = run_method(&dataset, &m, &opts)?;
                let (cached, saved) = r
                    .reports
                    .last()
                    .map(|rep| {
                        (
                            rep.avg_cached_inputs,
                            rep.transfer.bytes_saved_by_cache,
                        )
                    })
                    .unwrap_or((f64::NAN, 0));
                let pname = match &policy {
                    CachePolicy::Degree => "degree",
                    CachePolicy::RandomWalk { .. } => "random-walk",
                    CachePolicy::Uniform => "uniform",
                };
                println!(
                    "{:<12} {:>7.2} {:>8} {:>8.4} {:>14.0} {:>14}",
                    pname,
                    100.0 * frac,
                    period,
                    r.test_f1,
                    cached,
                    gns::util::fmt_bytes(saved)
                );
            }
        }
    }
    println!(
        "\nReading: degree policy should dominate uniform; random-walk wins\n\
         when the train split is small. Larger caches + shorter periods give\n\
         more cached inputs; accuracy should be flat at 1% (paper Table 6)."
    );
    Ok(())
}
