"""Layer-1 Pallas kernel: importance-weighted neighbor aggregation.

This is the compute hot-spot of GNS mini-batch training: every GraphSAGE
layer aggregates K sampled neighbors per output node, scaled by the
importance-sampling coefficients of Section 3.4 of the paper,

    out[v, :] = sum_k w[v, k] * h[idx[v, k], :].

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's bottleneck is a
CPU->GPU feature copy followed by a sparse gather+mean on the GPU. On TPU
the analogous schedule tiles the *output* rows into VMEM-resident blocks
(BlockSpec over rows), streams the index/weight tiles alongside, and keeps
the embedding table ``h`` in HBM-backed memory accessed by the gather. The
weighted reduction over K is a small dense contraction that feeds the MXU
matmul of the surrounding SAGE layer.

The kernel MUST be run with interpret=True in this environment: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
interpret=True lowers to plain HLO, which is exactly what the AOT bridge
(aot.py) needs.

``gather_scaled_sum`` wraps the kernel in a jax.custom_vjp so the L2 model
can be differentiated: pallas_call has no autodiff rule, so the backward
pass is expressed against the reference semantics (a scatter-add for dh and
a batched dot for dw — see kernels/ref.py). The forward pallas path and the
reference are asserted allclose in python/tests/test_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows of the output processed per grid step. 128 aligns with the TPU
# lane dimension; the row blocking is what bounds the VMEM working set.
DEFAULT_BLOCK_ROWS = 128


def _gather_agg_kernel(h_ref, idx_ref, w_ref, o_ref):
    """One grid step: aggregate a [R, K] tile of neighbor lists.

    h_ref:   [N_prev, D]  whole embedding table (HBM-resident on real HW).
    idx_ref: [R, K]       this block's neighbor indices.
    w_ref:   [R, K]       this block's importance coefficients.
    o_ref:   [R, D]       output tile.
    """
    idx = idx_ref[...]
    w = w_ref[...].astype(o_ref.dtype)
    h = h_ref[...]
    # [R, K, D] gather then weighted reduction over K. In interpret mode the
    # gather lowers to an HLO gather; on TPU Mosaic this becomes a dynamic
    # VMEM load per (row, k) with the reduction kept in registers.
    g = jnp.take(h, idx, axis=0)
    o_ref[...] = jnp.einsum("nk,nkd->nd", w, g).astype(o_ref.dtype)


def gather_scaled_sum_pallas(h, idx, w, *, block_rows=DEFAULT_BLOCK_ROWS):
    """Raw pallas_call wrapper (forward only, not differentiable)."""
    n, k = idx.shape
    d = h.shape[1]
    rows = min(block_rows, n)
    # Grid over row tiles; pad is unnecessary because BlockSpec index_map
    # clamps — we require n % rows == 0 and pad at the caller otherwise.
    if n % rows != 0:
        pad = rows - n % rows
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        out = gather_scaled_sum_pallas(h, idx, w, block_rows=rows)
        return out[:n]
    grid = (n // rows,)
    return pl.pallas_call(
        _gather_agg_kernel,
        grid=grid,
        in_specs=[
            # Whole table every step: the gather indexes arbitrarily into it.
            pl.BlockSpec(h.shape, lambda i: (0, 0)),
            pl.BlockSpec((rows, k), lambda i: (i, 0)),
            pl.BlockSpec((rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(h, idx, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def gather_scaled_sum(h, idx, w):
    """Differentiable importance-weighted aggregation (Pallas forward)."""
    return gather_scaled_sum_pallas(h, idx, w)


def _fwd(h, idx, w):
    return gather_scaled_sum_pallas(h, idx, w), (h, idx, w)


def _bwd(res, g_out):
    h, idx, w = res
    dh, dw = ref.gather_scaled_sum_bwd_ref(h, idx, w, g_out)
    return dh, None, dw


gather_scaled_sum.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(n_prev, d, k, *, block_rows=DEFAULT_BLOCK_ROWS,
                         dtype_bytes=4, table_resident=True):
    """Estimated VMEM working set of one grid step (EXPERIMENTS.md §Perf).

    With table_resident=True the whole embedding table h is pinned in VMEM
    alongside the row tile — valid for the padded level sizes of this
    repo's model configs (≤ 12000×100 f32 ≈ 4.8 MiB). For giant input
    levels the table must stay HBM-resident (table_resident=False) and the
    gather streams rows; the tile cost is then independent of n_prev.
    """
    rows = min(block_rows, 1 << 30)
    tile = rows * d * dtype_bytes          # out tile
    tile += 2 * rows * k * dtype_bytes     # idx + w tiles
    tile += rows * k * d * dtype_bytes     # gathered [R, K, D] intermediate
    if table_resident:
        tile += n_prev * d * dtype_bytes
    return tile
