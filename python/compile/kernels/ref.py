"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas kernels are tested against
(python/tests/test_kernel.py sweeps shapes/dtypes with hypothesis and
asserts allclose). They are also used by the L2 model's custom-VJP
backward pass where a scatter is cheaper to express in plain jnp.
"""

import jax.numpy as jnp


def gather_scaled_sum_ref(h, idx, w):
    """Importance-weighted neighbor aggregation (the GNS hot-spot).

    out[v, :] = sum_k w[v, k] * h[idx[v, k], :]

    Args:
      h:   [N_prev, D] float  — previous-level node embeddings.
      idx: [N, K]      int32  — neighbor indices into ``h`` (padding entries
                                may point anywhere; they must carry w == 0).
      w:   [N, K]      float  — importance-sampling coefficients; 0 for padding.

    Returns:
      [N, D] float — aggregated neighborhood embeddings.
    """
    g = jnp.take(h, idx, axis=0)  # [N, K, D]
    return jnp.einsum("nk,nkd->nd", w.astype(h.dtype), g)


def gather_scaled_sum_bwd_ref(h, idx, w, g_out):
    """Reference VJP of gather_scaled_sum w.r.t. (h, w).

    dh[j]   = sum_{(v,k): idx[v,k]==j} w[v,k] * g_out[v]
    dw[v,k] = <g_out[v], h[idx[v,k]]>
    """
    n_prev, d = h.shape
    contrib = w[..., None].astype(h.dtype) * g_out[:, None, :]  # [N, K, D]
    dh = jnp.zeros((n_prev, d), h.dtype).at[idx.reshape(-1)].add(
        contrib.reshape(-1, d)
    )
    gathered = jnp.take(h, idx, axis=0)  # [N, K, D]
    dw = jnp.einsum("nkd,nd->nk", gathered, g_out).astype(w.dtype)
    return dh, dw


def sage_layer_ref(h_prev, self_idx, idx, w, weight, bias, relu=True):
    """One GraphSAGE layer: concat(self, weighted-agg) -> affine -> relu."""
    agg = gather_scaled_sum_ref(h_prev, idx, w)
    h_self = jnp.take(h_prev, self_idx, axis=0)
    z = jnp.concatenate([h_self, agg], axis=1) @ weight + bias
    return jnp.maximum(z, 0.0) if relu else z
