"""AOT bridge: lower the L2 model to HLO *text* artifacts for the rust runtime.

Why HLO text and not ``lowered.compile().serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The HLO text
parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--config NAME ...]

Emits, per config:
    artifacts/<name>/train.hlo.txt   — fused fwd+bwd+Adam step
    artifacts/<name>/eval.hlo.txt    — forward-only (logits)
    artifacts/<name>/meta.json       — shapes + argument order contract

The rust side (rust/src/runtime) loads meta.json, validates its own block
shapes against it, and compiles both modules on the PJRT CPU client.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    make_eval_fn,
    make_train_fn,
    train_arg_specs,
    eval_arg_specs,
)

# --------------------------------------------------------------------------
# Config registry: one entry per synthetic dataset analogue / experiment.
# level_sizes are padded capacities; the sampler guarantees it never
# produces more nodes per level (it deduplicates, then truncates
# pathological batches — see rust/src/sampling/mod.rs).
# --------------------------------------------------------------------------

CONFIGS = {}


def _register(cfg: ModelConfig):
    CONFIGS[cfg.name] = cfg


def _capacities(batch: int, fanouts):
    """Worst-case level capacities: every node brings fanout+1 children."""
    sizes = [batch]
    for k in reversed(fanouts):
        sizes.append(sizes[-1] * (k + 1))
    return tuple(reversed(sizes))


def _mk(name, feature_dim, hidden_dim, num_classes, batch, fanouts,
        input_cap=None, use_pallas=True):
    fanouts = tuple(fanouts)
    caps = list(_capacities(batch, fanouts))
    if input_cap is not None:
        # Cap every level: levels are node-subsets of the level below, so
        # capacities must be non-increasing toward the output.
        caps = [min(c, input_cap) for c in caps]
    _register(ModelConfig(
        name=name,
        num_layers=len(fanouts),
        feature_dim=feature_dim,
        hidden_dim=hidden_dim,
        num_classes=num_classes,
        batch_size=batch,
        level_sizes=tuple(caps),
        fanouts=fanouts,
    ))


# Tiny config: fast artifact for unit/integration tests.
_mk("tiny", feature_dim=16, hidden_dim=16, num_classes=5, batch=64,
    fanouts=(3, 3), input_cap=1024)

# Paper-shaped 3-layer GraphSage configs for the five synthetic dataset
# analogues (DESIGN.md §Datasets). Fanouts follow the paper: 5,10,15 from
# the input layer up; batch 1000 reduced to 256 to keep CPU steps fast.
#
# Three padded-shape variants per dataset (XLA needs static shapes; each
# sampler family genuinely produces different level sizes — measured on the
# analogues with ~1.7x headroom):
#   <ds>          — NS / LazyGCN / LADIES(512) blocks.
#   <ds>_gns      — GNS blocks: cache-prioritized sampling collapses the
#                   lower levels (Table 4), so the padded block — and with
#                   it the per-step copy + compute — is much smaller.
#   <ds>_ladies5k — LADIES(5000): each level adds up to s_layer nodes.
_DATASETS = {
    # name: (feature_dim, num_classes)
    "yelp": (64, 20),
    "amazon": (100, 25),
    "oag": (256, 30),
    "products": (100, 47),
    "papers": (128, 32),
}


def _mk_levels(name, feature_dim, num_classes, levels):
    fanouts = (5, 10, 15)
    _register(ModelConfig(
        name=name,
        num_layers=3,
        feature_dim=feature_dim,
        hidden_dim=64,
        num_classes=num_classes,
        batch_size=256,
        level_sizes=tuple(levels),
        fanouts=fanouts,
    ))


for _ds, (_f, _c) in _DATASETS.items():
    _mk_levels(_ds, _f, _c, (20000, 12000, 2048, 256))
    _mk_levels(f"{_ds}_gns", _f, _c, (4000, 3000, 2048, 256))
    _mk_levels(f"{_ds}_ladies5k", _f, _c, (16000, 11000, 5500, 256))

DEFAULT_CONFIGS = ["tiny"] + [
    f"{ds}{suffix}" for ds in _DATASETS for suffix in ("", "_gns", "_ladies5k")
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: ModelConfig, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)

    train = jax.jit(make_train_fn(cfg)).lower(*train_arg_specs(cfg))
    with open(os.path.join(out_dir, "train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(train))

    ev = jax.jit(make_eval_fn(cfg)).lower(*eval_arg_specs(cfg))
    with open(os.path.join(out_dir, "eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(ev))

    meta = cfg.to_meta()
    meta["train_num_outputs"] = 6 * cfg.num_layers + 2
    meta["arg_order"] = (
        ["param"] * (2 * cfg.num_layers)
        + ["adam_m"] * (2 * cfg.num_layers)
        + ["adam_v"] * (2 * cfg.num_layers)
        + ["t", "lr", "x0"]
        + [f"layer{l}:{part}" for l in range(1, cfg.num_layers + 1)
           for part in ("self_idx", "idx", "w")]
        + ["labels", "mask"]
    )
    meta["eval_arg_order"] = (
        ["param"] * (2 * cfg.num_layers)
        + ["x0"]
        + [f"layer{l}:{part}" for l in range(1, cfg.num_layers + 1)
           for part in ("self_idx", "idx", "w")]
    )
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", action="append", default=None,
                    help="config name (repeatable); default: %s" % DEFAULT_CONFIGS)
    args = ap.parse_args()
    names = args.config or DEFAULT_CONFIGS
    for name in names:
        cfg = CONFIGS[name]
        out = os.path.join(args.out_dir, name)
        print(f"[aot] lowering config {name!r} -> {out}")
        lower_config(cfg, out)
        for fn in ("train.hlo.txt", "eval.hlo.txt"):
            sz = os.path.getsize(os.path.join(out, fn))
            print(f"[aot]   {fn}: {sz} bytes")


if __name__ == "__main__":
    main()
