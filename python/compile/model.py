"""Layer-2 JAX model: L-layer GraphSAGE over padded fixed-shape mini-batches.

This is the compute graph the rust coordinator (L3) drives via PJRT. It is
authored once in JAX, calls the Pallas aggregation kernel (L1) in every
layer, and is AOT-lowered to HLO text by aot.py. Python never runs on the
training path.

Mini-batch block format (fixed shapes — the coordinator pads):

  A mini-batch for an L-layer model consists of L+1 *levels* of nodes.
  Level L holds the B target nodes; level 0 holds the input nodes whose raw
  features are copied to the device. Level l-1 -> level l is one GraphSAGE
  layer. For each layer l (1-based):

    idx_l  [N_l, K_l] int32  — for each level-l node, K_l sampled-neighbor
                               positions into the level-(l-1) arrays.
                               Padding entries may point anywhere (use 0)
                               but must carry w == 0.
    w_l    [N_l, K_l] f32    — importance-sampling coefficients of GNS
                               §3.4 (for plain NS: 1/k_v for real entries).
                               The coordinator folds all normalization in,
                               so the kernel computes a plain weighted sum.
    self_l [N_l]      int32  — position of the node's own row in level l-1
                               (every level-l node is also a level-(l-1)
                               node by construction).

  x0     [N_0, F] f32   — input features, assembled by L3 from the GPU
                          cache (device-resident) + host slices.
  labels [B] int32, label_mask [B] f32 — padded targets.

Parameters per layer: W [2*D_{l-1}, D_l], b [D_l] (concat(self, agg)
aggregator of GraphSAGE). ReLU between layers, the last layer emits class
logits directly. Optimizer (Adam) lives *inside* the train-step graph so
the device round-trips only mini-batch data, never parameters.
"""

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.gather_agg import gather_scaled_sum
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape/config information for one AOT artifact."""

    name: str = "default"
    num_layers: int = 3
    feature_dim: int = 100
    hidden_dim: int = 256
    num_classes: int = 47
    batch_size: int = 1000
    # level_sizes[0] = input-node capacity ... level_sizes[L] = batch_size.
    level_sizes: Tuple[int, ...] = (60000, 12000, 1024, 1000)
    # fanouts[l-1] = K_l for layer l (level l-1 -> level l).
    fanouts: Tuple[int, ...] = (5, 10, 15)
    use_pallas: bool = True

    def __post_init__(self):
        assert len(self.level_sizes) == self.num_layers + 1
        assert len(self.fanouts) == self.num_layers
        assert self.level_sizes[-1] == self.batch_size

    def layer_dims(self) -> List[Tuple[int, int]]:
        dims = [self.feature_dim] + [self.hidden_dim] * (self.num_layers - 1)
        dims.append(self.num_classes)
        return [(dims[i], dims[i + 1]) for i in range(self.num_layers)]

    def to_meta(self) -> dict:
        return {
            "name": self.name,
            "num_layers": self.num_layers,
            "feature_dim": self.feature_dim,
            "hidden_dim": self.hidden_dim,
            "num_classes": self.num_classes,
            "batch_size": self.batch_size,
            "level_sizes": list(self.level_sizes),
            "fanouts": list(self.fanouts),
        }


def init_params(cfg: ModelConfig, key) -> List[jnp.ndarray]:
    """Glorot-ish init. Returned flat as [W1, b1, W2, b2, ...]."""
    params = []
    for (d_in, d_out) in cfg.layer_dims():
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / (2 * d_in + d_out)).astype(jnp.float32)
        params.append(jax.random.normal(sub, (2 * d_in, d_out), jnp.float32) * scale)
        params.append(jnp.zeros((d_out,), jnp.float32))
    return params


def _aggregate(cfg: ModelConfig, h_prev, idx, w):
    if cfg.use_pallas:
        return gather_scaled_sum(h_prev, idx, w)
    return kref.gather_scaled_sum_ref(h_prev, idx, w)


def forward(cfg: ModelConfig, params, x0, self_idx, idx, w):
    """Run the L layers; returns logits [B, C].

    self_idx/idx/w are lists of per-layer block tensors (layer 1 first).
    """
    h = x0
    n_layers = cfg.num_layers
    for l in range(n_layers):
        weight = params[2 * l]
        bias = params[2 * l + 1]
        agg = _aggregate(cfg, h, idx[l], w[l])
        h_self = jnp.take(h, self_idx[l], axis=0)
        z = jnp.concatenate([h_self, agg], axis=1) @ weight + bias
        h = jnp.maximum(z, 0.0) if l < n_layers - 1 else z
    return h


def masked_softmax_xent(logits, labels, mask):
    """Mean masked softmax cross-entropy; also returns correct-count."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = -(ll * mask).sum() / denom
    pred = jnp.argmax(logits, axis=-1)
    correct = ((pred == labels).astype(jnp.float32) * mask).sum()
    return loss, correct


def loss_fn(cfg: ModelConfig, params, batch):
    x0, self_idx, idx, w, labels, mask = batch
    logits = forward(cfg, params, x0, self_idx, idx, w)
    loss, correct = masked_softmax_xent(logits, labels, mask)
    return loss, (logits, correct)


ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def train_step(cfg: ModelConfig, params, m, v, t, lr,
               x0, self_idx, idx, w, labels, mask):
    """One SGD step with in-graph Adam.

    Returns (new_params, new_m, new_v, loss, correct).
    t is the 1-based step counter (f32 scalar) for bias correction.
    """
    batch = (x0, self_idx, idx, w, labels, mask)
    (loss, (_, correct)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)
    new_params, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
        update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_params.append(p - update)
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, loss, correct


def batch_specs(cfg: ModelConfig):
    """ShapeDtypeStructs for the mini-batch tensors, layer-major order.

    Order: x0, then per-layer (self_idx_l, idx_l, w_l), then labels, mask.
    This order is mirrored in meta.json and consumed by the rust runtime.
    """
    f32, i32 = jnp.float32, jnp.int32
    specs = [jax.ShapeDtypeStruct((cfg.level_sizes[0], cfg.feature_dim), f32)]
    for l in range(cfg.num_layers):
        n_l = cfg.level_sizes[l + 1]
        k_l = cfg.fanouts[l]
        specs.append(jax.ShapeDtypeStruct((n_l,), i32))        # self_idx
        specs.append(jax.ShapeDtypeStruct((n_l, k_l), i32))    # idx
        specs.append(jax.ShapeDtypeStruct((n_l, k_l), f32))    # w
    specs.append(jax.ShapeDtypeStruct((cfg.batch_size,), i32))  # labels
    specs.append(jax.ShapeDtypeStruct((cfg.batch_size,), f32))  # mask
    return specs


def param_specs(cfg: ModelConfig):
    f32 = jnp.float32
    specs = []
    for (d_in, d_out) in cfg.layer_dims():
        specs.append(jax.ShapeDtypeStruct((2 * d_in, d_out), f32))
        specs.append(jax.ShapeDtypeStruct((d_out,), f32))
    return specs


def _unpack_batch(cfg: ModelConfig, flat):
    x0 = flat[0]
    self_idx, idx, w = [], [], []
    pos = 1
    for _ in range(cfg.num_layers):
        self_idx.append(flat[pos]); idx.append(flat[pos + 1]); w.append(flat[pos + 2])
        pos += 3
    labels, mask = flat[pos], flat[pos + 1]
    return x0, self_idx, idx, w, labels, mask


def make_train_fn(cfg: ModelConfig):
    """Flat-signature train step for AOT export.

    Signature: (params..., m..., v..., t, lr, batch...) ->
               (params..., m..., v..., loss, correct)
    """
    n_params = 2 * cfg.num_layers

    def fn(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        t = args[3 * n_params]
        lr = args[3 * n_params + 1]
        flat_batch = args[3 * n_params + 2:]
        x0, self_idx, idx, w, labels, mask = _unpack_batch(cfg, flat_batch)
        new_p, new_m, new_v, loss, correct = train_step(
            cfg, params, m, v, t, lr, x0, self_idx, idx, w, labels, mask
        )
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, correct)

    return fn


def make_eval_fn(cfg: ModelConfig):
    """Flat-signature forward pass: (params..., batch-sans-labels) -> (logits,).

    labels/mask are intentionally NOT arguments: jax.jit DCEs unused entry
    parameters during lowering, which would silently shift the argument
    order the rust runtime relies on. The eval contract is therefore
    params + x0 + per-layer (self_idx, idx, w).
    """
    n_params = 2 * cfg.num_layers

    def fn(*args):
        params = list(args[:n_params])
        flat = args[n_params:]
        x0 = flat[0]
        self_idx, idx, w = [], [], []
        pos = 1
        for _ in range(cfg.num_layers):
            self_idx.append(flat[pos]); idx.append(flat[pos + 1]); w.append(flat[pos + 2])
            pos += 3
        return (forward(cfg, params, x0, self_idx, idx, w),)

    return fn


def train_arg_specs(cfg: ModelConfig):
    f32 = jnp.float32
    ps = param_specs(cfg)
    scalar = jax.ShapeDtypeStruct((), f32)
    return ps + ps + ps + [scalar, scalar] + batch_specs(cfg)


def eval_arg_specs(cfg: ModelConfig):
    # batch specs minus trailing labels/mask (see make_eval_fn docstring)
    return param_specs(cfg) + batch_specs(cfg)[:-2]
