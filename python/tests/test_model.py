"""L2 correctness: GraphSAGE model, loss, Adam step, and batch contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref as kref


def tiny_cfg(use_pallas=True):
    return M.ModelConfig(
        name="t", num_layers=2, feature_dim=8, hidden_dim=8, num_classes=4,
        batch_size=16, level_sizes=(128, 48, 16), fanouts=(3, 2),
        use_pallas=use_pallas,
    )


def rand_batch(cfg, rng, learnable=False):
    """A structurally valid random batch.

    With learnable=True, features directly encode the label so a correct
    implementation must drive the loss toward zero.
    """
    labels = rng.integers(0, cfg.num_classes, size=(cfg.batch_size,)).astype(np.int32)
    x0 = rng.standard_normal((cfg.level_sizes[0], cfg.feature_dim)).astype(np.float32)
    self_idx, idx, w = [], [], []
    # level l nodes are the first N_l rows of level l-1 (subset invariant)
    for l in range(cfg.num_layers):
        n, k = cfg.level_sizes[l + 1], cfg.fanouts[l]
        prev = cfg.level_sizes[l]
        self_idx.append(np.arange(n, dtype=np.int32))
        idx.append(rng.integers(0, prev, size=(n, k)).astype(np.int32))
        w.append(np.full((n, k), 1.0 / k, np.float32))
    if learnable:
        # plant the label into the self-feature path of the targets
        for b in range(cfg.batch_size):
            x0[b] = 0.0
            x0[b, labels[b] % cfg.feature_dim] = 3.0
    mask = np.ones((cfg.batch_size,), np.float32)
    return tuple(jnp.asarray(a) for a in (x0,)) + (
        [jnp.asarray(a) for a in self_idx],
        [jnp.asarray(a) for a in idx],
        [jnp.asarray(a) for a in w],
        jnp.asarray(labels),
        jnp.asarray(mask),
    )


def test_forward_shapes():
    cfg = tiny_cfg()
    rng = np.random.default_rng(0)
    x0, si, ix, w, labels, mask = rand_batch(cfg, rng)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    logits = M.forward(cfg, params, x0, si, ix, w)
    assert logits.shape == (cfg.batch_size, cfg.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_pallas_vs_ref_path():
    """use_pallas=True and False must produce identical logits."""
    rng = np.random.default_rng(1)
    cfg_p, cfg_r = tiny_cfg(True), tiny_cfg(False)
    x0, si, ix, w, labels, mask = rand_batch(cfg_p, rng)
    params = M.init_params(cfg_p, jax.random.PRNGKey(1))
    lp = M.forward(cfg_p, params, x0, si, ix, w)
    lr = M.forward(cfg_r, params, x0, si, ix, w)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-5, atol=1e-5)


def test_masked_loss_ignores_padding():
    cfg = tiny_cfg()
    rng = np.random.default_rng(2)
    x0, si, ix, w, labels, mask = rand_batch(cfg, rng)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    logits = M.forward(cfg, params, x0, si, ix, w)
    full, _ = M.masked_softmax_xent(logits, labels, mask)
    # Mask half the batch and corrupt the masked labels — loss over the kept
    # half must be unchanged by the corruption.
    half_mask = mask.at[8:].set(0.0)
    corrupted = labels.at[8:].set((labels[8:] + 1) % cfg.num_classes)
    a, _ = M.masked_softmax_xent(logits, labels, half_mask)
    b, _ = M.masked_softmax_xent(logits, corrupted, half_mask)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_train_step_decreases_loss_on_learnable_batch():
    cfg = tiny_cfg()
    rng = np.random.default_rng(3)
    x0, si, ix, w, labels, mask = rand_batch(cfg, rng, learnable=True)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jax.jit(lambda p, m, v, t: M.train_step(
        cfg, p, m, v, t, jnp.float32(0.01), x0, si, ix, w, labels, mask))
    losses = []
    for t in range(1, 41):
        params, m, v, loss, correct = step(params, m, v, jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]
    assert losses[-1] < 0.7


def test_adam_bias_correction_first_step():
    """After one step from zero moments, update ≈ lr * sign(grad)."""
    cfg = tiny_cfg(use_pallas=False)
    rng = np.random.default_rng(4)
    x0, si, ix, w, labels, mask = rand_batch(cfg, rng)
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    lr = 0.01
    batch = (x0, si, ix, w, labels, mask)
    (_, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
    new_p, _, _, _, _ = M.train_step(
        cfg, params, m, v, jnp.float32(1.0), jnp.float32(lr),
        x0, si, ix, w, labels, mask)
    for p, np_, g in zip(params, new_p, grads):
        delta = np.asarray(p - np_)
        g = np.asarray(g)
        big = np.abs(g) > 1e-4
        if big.any():
            np.testing.assert_allclose(
                delta[big], lr * np.sign(g)[big], rtol=1e-2, atol=1e-4)


def test_flat_train_fn_round_trip():
    """make_train_fn flat signature == structured train_step."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(5)
    x0, si, ix, w, labels, mask = rand_batch(cfg, rng)
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    flat_batch = [x0]
    for l in range(cfg.num_layers):
        flat_batch += [si[l], ix[l], w[l]]
    flat_batch += [labels, mask]
    fn = M.make_train_fn(cfg)
    outs = fn(*(params + m + v + [jnp.float32(1.0), jnp.float32(1e-3)] + flat_batch))
    sp, sm, sv, sl, sc = M.train_step(
        cfg, params, m, v, jnp.float32(1.0), jnp.float32(1e-3),
        x0, si, ix, w, labels, mask)
    np.testing.assert_allclose(float(outs[-2]), float(sl), rtol=1e-6)
    n = 2 * cfg.num_layers
    for a, b in zip(outs[:n], sp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_eval_fn_matches_forward():
    cfg = tiny_cfg()
    rng = np.random.default_rng(6)
    x0, si, ix, w, labels, mask = rand_batch(cfg, rng)
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    flat_batch = [x0]
    for l in range(cfg.num_layers):
        flat_batch += [si[l], ix[l], w[l]]
    (logits,) = M.make_eval_fn(cfg)(*(params + flat_batch))
    want = M.forward(cfg, params, x0, si, ix, w)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-6)


def test_batch_specs_order_and_shapes():
    cfg = tiny_cfg()
    specs = M.batch_specs(cfg)
    assert specs[0].shape == (cfg.level_sizes[0], cfg.feature_dim)
    assert specs[-2].shape == (cfg.batch_size,)
    assert specs[-1].shape == (cfg.batch_size,)
    assert len(specs) == 1 + 3 * cfg.num_layers + 2


def test_sage_layer_ref_known_values():
    """Hand-computed single layer."""
    h = jnp.asarray([[1.0], [2.0]], jnp.float32)
    self_idx = jnp.asarray([0], jnp.int32)
    idx = jnp.asarray([[1, 1]], jnp.int32)
    w = jnp.asarray([[0.5, 0.5]], jnp.float32)
    weight = jnp.asarray([[1.0], [10.0]], jnp.float32)  # [2*1, 1]
    bias = jnp.asarray([0.5], jnp.float32)
    out = kref.sage_layer_ref(h, self_idx, idx, w, weight, bias, relu=False)
    # concat(self=1, agg=2) @ [[1],[10]] + .5 = 1 + 20 + .5
    np.testing.assert_allclose(np.asarray(out), [[21.5]], rtol=1e-6)
