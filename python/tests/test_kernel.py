"""L1 correctness: Pallas gather-aggregate kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot. hypothesis
sweeps shapes/dtypes; every case asserts allclose against kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional tooling: skip this module cleanly (instead of a
# collection error) on environments without it
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gather_agg import (
    gather_scaled_sum,
    gather_scaled_sum_pallas,
)


def make_case(rng, n_prev, n, k, d, dtype=np.float32):
    h = rng.standard_normal((n_prev, d)).astype(dtype)
    idx = rng.integers(0, n_prev, size=(n, k)).astype(np.int32)
    w = (rng.random((n, k)) / k).astype(dtype)
    # sprinkle padding entries: w == 0, idx arbitrary
    pad = rng.random((n, k)) < 0.2
    w[pad] = 0.0
    return jnp.asarray(h), jnp.asarray(idx), jnp.asarray(w)


# ---------------------------------------------------------------------------
# hypothesis sweep: shapes and dtypes
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_prev=st.integers(1, 300),
    n=st.integers(1, 300),
    k=st.integers(1, 16),
    d=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_shapes(n_prev, n, k, d, seed):
    rng = np.random.default_rng(seed)
    h, idx, w = make_case(rng, n_prev, n, k, d)
    got = gather_scaled_sum_pallas(h, idx, w)
    want = ref.gather_scaled_sum_ref(h, idx, w)
    assert got.shape == (n, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.float64]),
    block_rows=st.sampled_from([1, 7, 64, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_dtypes_and_blocking(dtype, block_rows, seed):
    rng = np.random.default_rng(seed)
    h, idx, w = make_case(rng, 120, 90, 5, 33, dtype=dtype)
    got = gather_scaled_sum_pallas(h, idx, w, block_rows=block_rows)
    want = ref.gather_scaled_sum_ref(h, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# targeted edge cases
# ---------------------------------------------------------------------------

def test_all_padding_rows_give_zero():
    h = jnp.ones((10, 4), jnp.float32)
    idx = jnp.zeros((6, 3), jnp.int32)
    w = jnp.zeros((6, 3), jnp.float32)
    out = gather_scaled_sum_pallas(h, idx, w)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((6, 4), np.float32))


def test_duplicate_neighbor_indices_accumulate():
    h = jnp.asarray([[1.0, 2.0], [10.0, 20.0]], jnp.float32)
    idx = jnp.asarray([[1, 1, 1]], jnp.int32)
    w = jnp.asarray([[0.5, 0.25, 0.25]], jnp.float32)
    out = gather_scaled_sum_pallas(h, idx, w)
    np.testing.assert_allclose(np.asarray(out), [[10.0, 20.0]], rtol=1e-6)


def test_mean_aggregation_via_weights():
    """w = 1/k recovers the plain GraphSAGE mean aggregator."""
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.standard_normal((50, 8)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 50, size=(20, 4)).astype(np.int32))
    w = jnp.full((20, 4), 0.25, jnp.float32)
    out = gather_scaled_sum_pallas(h, idx, w)
    want = np.asarray(jnp.take(h, idx, axis=0)).mean(axis=1)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)


def test_importance_weight_expectation_unbiased():
    """E[sum_k w_k h_{i_k}] over cache resamples == full-neighborhood sum.

    Statistical check of eq. (5)/(10): sampling k of deg neighbors uniformly
    with w = deg/k is an unbiased estimator of the full-neighborhood sum.
    """
    rng = np.random.default_rng(7)
    deg, k, d, trials = 12, 4, 6, 4000
    h = rng.standard_normal((deg, d)).astype(np.float32)
    target = h.sum(axis=0)
    acc = np.zeros(d, np.float32)
    hj = jnp.asarray(h)
    for _ in range(trials):
        sel = rng.choice(deg, size=k, replace=False).astype(np.int32)
        w = np.full((1, k), deg / k, np.float32)
        out = ref.gather_scaled_sum_ref(hj, jnp.asarray(sel[None, :]), jnp.asarray(w))
        acc += np.asarray(out)[0]
    est = acc / trials
    np.testing.assert_allclose(est, target, atol=0.35)


# ---------------------------------------------------------------------------
# custom VJP
# ---------------------------------------------------------------------------

def test_custom_vjp_matches_autodiff_of_ref():
    rng = np.random.default_rng(11)
    h, idx, w = make_case(rng, 40, 30, 3, 7)

    def f_pallas(h, w):
        return (gather_scaled_sum(h, idx, w) ** 2).sum()

    def f_ref(h, w):
        return (ref.gather_scaled_sum_ref(h, idx, w) ** 2).sum()

    gh_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(h, w)
    gh_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(np.asarray(gh_p), np.asarray(gh_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r), rtol=1e-4, atol=1e-5)


def test_bwd_ref_scatter_matches_dense_jacobian():
    """dh from the hand-written scatter equals a dense one-hot contraction."""
    rng = np.random.default_rng(13)
    h, idx, w = make_case(rng, 15, 10, 2, 3)
    g_out = jnp.asarray(rng.standard_normal((10, 3)).astype(np.float32))
    dh, dw = ref.gather_scaled_sum_bwd_ref(h, idx, w, g_out)
    # dense check
    one_hot = np.zeros((10, 2, 15), np.float32)
    idx_np = np.asarray(idx)
    for v in range(10):
        for k in range(2):
            one_hot[v, k, idx_np[v, k]] = 1.0
    dh_dense = np.einsum("vk,vkj,vd->jd", np.asarray(w), one_hot, np.asarray(g_out))
    np.testing.assert_allclose(np.asarray(dh), dh_dense, rtol=1e-5, atol=1e-6)


def test_kernel_under_jit_and_vmap_free_shapes():
    """The kernel must stay valid under jit (the AOT path always jits)."""
    rng = np.random.default_rng(17)
    h, idx, w = make_case(rng, 64, 64, 4, 16)
    got = jax.jit(gather_scaled_sum_pallas)(h, idx, w)
    want = ref.gather_scaled_sum_ref(h, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
