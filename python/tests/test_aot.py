"""AOT bridge contract: HLO text artifacts + meta.json stay loadable.

The rust runtime depends on: (a) HLO text parsable by xla_extension 0.5.1
(validated rust-side in rust/tests/runtime_e2e.rs), (b) the argument-order
contract in meta.json, (c) parameter shapes derivable from the config.
These tests pin (b) and (c) and smoke the text emission for the tiny config.
"""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M


def test_config_registry_contains_defaults():
    for name in aot.DEFAULT_CONFIGS:
        assert name in aot.CONFIGS


def test_capacities_monotone_and_consistent():
    for cfg in aot.CONFIGS.values():
        assert cfg.level_sizes[-1] == cfg.batch_size
        for a, b in zip(cfg.level_sizes, cfg.level_sizes[1:]):
            assert a >= b, f"{cfg.name}: level capacities must shrink upward"
        # worst-case growth bound: N_{l-1} <= N_l * (K_l + 1) unless capped
        for l in range(cfg.num_layers):
            cap = cfg.level_sizes[l + 1] * (cfg.fanouts[l] + 1)
            assert cfg.level_sizes[l] <= max(cap, cfg.level_sizes[0])


def test_arg_specs_count_matches_meta_contract():
    cfg = aot.CONFIGS["tiny"]
    n_params = 2 * cfg.num_layers
    train_specs = M.train_arg_specs(cfg)
    # params + m + v + (t, lr) + batch
    assert len(train_specs) == 3 * n_params + 2 + len(M.batch_specs(cfg))
    eval_specs = M.eval_arg_specs(cfg)
    assert len(eval_specs) == n_params + len(M.batch_specs(cfg)) - 2


def test_lower_tiny_config_emits_artifacts(tmp_path):
    cfg = aot.CONFIGS["tiny"]
    out = tmp_path / "tiny"
    aot.lower_config(cfg, str(out))
    for fn in ("train.hlo.txt", "eval.hlo.txt", "meta.json"):
        p = out / fn
        assert p.exists() and p.stat().st_size > 0
    meta = json.loads((out / "meta.json").read_text())
    assert meta["batch_size"] == cfg.batch_size
    assert meta["level_sizes"] == list(cfg.level_sizes)
    assert meta["fanouts"] == list(cfg.fanouts)
    assert meta["train_num_outputs"] == 6 * cfg.num_layers + 2
    order = meta["arg_order"]
    assert order.count("param") == 2 * cfg.num_layers
    assert order[-2:] == ["labels", "mask"]
    # HLO text must declare an ENTRY computation (what the rust parser needs)
    text = (out / "train.hlo.txt").read_text()
    assert "ENTRY" in text
    assert "HloModule" in text


def test_hlo_text_has_fixed_param_count(tmp_path):
    cfg = aot.CONFIGS["tiny"]
    out = tmp_path / "tiny2"
    aot.lower_config(cfg, str(out))
    text = (out / "eval.hlo.txt").read_text()
    # eval takes params + batch tensors (sans labels/mask) as entry params
    n_expected = 2 * cfg.num_layers + len(M.batch_specs(cfg)) - 2
    assert text.count("parameter(") >= n_expected
    # the strong check: jax reports the same arity
    lowered = jax.jit(M.make_eval_fn(cfg)).lower(*M.eval_arg_specs(cfg))
    assert len(lowered.compiler_ir("stablehlo").body.operations[0].arguments) == n_expected
