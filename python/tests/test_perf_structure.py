"""L1/L2 structural performance checks (§Perf, DESIGN.md §8).

interpret=True gives CPU-numpy timings, which are NOT a TPU proxy — so the
perf gate on the kernel is *structural*: the VMEM working set of every
model config's aggregation tiles must fit a TPU core's ~16 MiB VMEM, and
the lowered HLO must stay free of accidental blowups (instruction-count
regression guard).
"""

import os

import pytest

from compile import aot
from compile import model as M
from compile.kernels.gather_agg import (
    DEFAULT_BLOCK_ROWS,
    vmem_footprint_bytes,
)

VMEM_BUDGET = 16 << 20  # 16 MiB per TensorCore


def _layer_dims(cfg):
    dims = [cfg.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
    dims.append(cfg.num_classes)
    return dims


@pytest.mark.parametrize("name", list(aot.CONFIGS))
def test_vmem_footprint_within_budget(name):
    cfg = aot.CONFIGS[name]
    dims = _layer_dims(cfg)
    for l in range(cfg.num_layers):
        n_prev = cfg.level_sizes[l]
        d = dims[l]
        k = cfg.fanouts[l]
        fp = vmem_footprint_bytes(n_prev, d, k, block_rows=DEFAULT_BLOCK_ROWS)
        if fp > VMEM_BUDGET:
            # large input tables fall back to HBM-resident streaming
            fp_stream = vmem_footprint_bytes(
                n_prev, d, k, block_rows=DEFAULT_BLOCK_ROWS, table_resident=False
            )
            assert fp_stream <= VMEM_BUDGET, (
                f"{name} layer {l}: streaming tile {fp_stream} exceeds VMEM"
            )


def test_block_rows_default_is_lane_aligned():
    assert DEFAULT_BLOCK_ROWS % 128 == 0


def test_hlo_instruction_counts_bounded(tmp_path):
    """Regression guard: the lowered train step must stay a few hundred
    instructions (a pallas/interpret change that explodes into thousands of
    scalar ops would silently wreck compile + run time)."""
    cfg = aot.CONFIGS["tiny"]
    out = tmp_path / "tiny_perf"
    aot.lower_config(cfg, str(out))
    text = (out / "train.hlo.txt").read_text()
    n_instr = text.count("\n  ")  # instruction lines are indented
    assert n_instr < 2500, f"train HLO blew up: {n_instr} instructions"
    n_gather = text.count(" gather(")
    assert n_gather >= cfg.num_layers  # one per layer at minimum
    assert n_gather <= 8 * cfg.num_layers, f"too many gathers: {n_gather}"


def test_gns_shapes_cut_flops_vs_ns_shapes():
    """The per-method artifact shapes are the L2 optimization that restores
    GNS's compute advantage under XLA's static shapes: the _gns config must
    have ≥2x fewer matmul FLOPs than the NS-shaped config."""

    def flops(cfg):
        dims = _layer_dims(cfg)
        total = 0
        for l in range(cfg.num_layers):
            rows = cfg.level_sizes[l + 1]
            total += 2 * rows * (2 * dims[l]) * dims[l + 1]
            total += 2 * rows * cfg.fanouts[l] * dims[l]
        return total

    ns = flops(aot.CONFIGS["products"])
    gns = flops(aot.CONFIGS["products_gns"])
    assert ns >= 2 * gns, f"ns={ns} gns={gns}"
